"""L1 Pallas kernel: batched decision-function scoring (Eq. 6).

scores = K(Xtest, Xtrain) @ (y * alpha)

Tiled over test rows: each grid step holds a [TT, F] test tile and the
whole [L, F] training set in VMEM, computes the Gram tile on the MXU and
immediately contracts it against (y*alpha) — the Gram tile never leaves
VMEM (this is the serving hot path of the Rust coordinator).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TT = 128  # test-row tile


def _pick(n: int, t: int) -> int:
    """Largest tile <= t dividing n (shapes are static at trace time)."""
    t = min(t, n)
    while n % t != 0:
        t -= 1
    return t


def _decision_rbf_kernel(gamma_ref, xt_ref, xtr_ref, ya_ref, o_ref):
    xt = xt_ref[...]  # [TT, F]
    xtr = xtr_ref[...]  # [L, F]
    cross = jnp.dot(xt, xtr.T, preferred_element_type=jnp.float32)
    n1 = jnp.sum(xt * xt, axis=1, keepdims=True)
    n2 = jnp.sum(xtr * xtr, axis=1, keepdims=True)
    d = jnp.maximum(n1 + n2.T - 2.0 * cross, 0.0)
    k = jnp.exp(-gamma_ref[0] * d)
    o_ref[...] = jnp.dot(k, ya_ref[...], preferred_element_type=jnp.float32)


def _decision_linear_kernel(xt_ref, xtr_ref, ya_ref, o_ref):
    cross = jnp.dot(xt_ref[...], xtr_ref[...].T, preferred_element_type=jnp.float32)
    o_ref[...] = jnp.dot(cross, ya_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tt",))
def decision_rbf(xt, xtr, yalpha, gamma, tt: int = TT):
    """xt: [T, F], xtr: [L, F], yalpha: [L], gamma: (1,)."""
    t, f = xt.shape
    l = xtr.shape[0]
    tt = _pick(t, tt)
    return pl.pallas_call(
        _decision_rbf_kernel,
        grid=(t // tt,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((tt, f), lambda i: (i, 0)),
            pl.BlockSpec((l, f), lambda i: (0, 0)),
            pl.BlockSpec((l,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        interpret=True,
    )(gamma, xt, xtr, yalpha)


@functools.partial(jax.jit, static_argnames=("tt",))
def decision_linear(xt, xtr, yalpha, tt: int = TT):
    t, f = xt.shape
    l = xtr.shape[0]
    tt = _pick(t, tt)
    return pl.pallas_call(
        _decision_linear_kernel,
        grid=(t // tt,),
        in_specs=[
            pl.BlockSpec((tt, f), lambda i: (i, 0)),
            pl.BlockSpec((l, f), lambda i: (0, 0)),
            pl.BlockSpec((l,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        interpret=True,
    )(xt, xtr, yalpha)
