"""L1 Pallas kernels for the screening rule's two hot ops.

1. `qmatvec` — row-tiled Q @ v.  This is Z_i . c for every i (the dominant
   cost of one screening step, O(l^2)).  Each grid step streams one row
   block of Q through VMEM exactly once.
2. `screen_codes` — the fused bound-evaluation epilogue of Corollary 3/4:
   given q = Qv, per-sample norms ||Z_i||, sqrt(r) and the rho bounds, emit
   the trinary keep/zero/upper code per sample in a single elementwise pass
   (no temporaries, one read of each input).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TB = 128  # row-block tile


def pick_tile(l: int, tb: int) -> int:
    """Largest tile <= tb that divides l (shapes are static at trace time)."""
    t = min(tb, l)
    while l % t != 0:
        t -= 1
    return t


def _matvec_kernel(q_ref, v_ref, o_ref):
    # q_ref: [TB, L] row block; v_ref: [L]; one fused MXU/VPU contraction.
    o_ref[...] = jnp.dot(q_ref[...], v_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tb",))
def qmatvec(q, v, tb: int = TB):
    """Q @ v with Q [L, L], v [L]; tb shrinks to a divisor of L."""
    l = q.shape[0]
    tb = pick_tile(l, tb)
    return pl.pallas_call(
        _matvec_kernel,
        grid=(l // tb,),
        in_specs=[
            pl.BlockSpec((tb, l), lambda i: (i, 0)),
            pl.BlockSpec((l,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((l,), jnp.float32),
        interpret=True,
    )(q, v)


def _screen_kernel(s_ref, qv_ref, n_ref, m_ref, o_ref):
    # s_ref: [3] scalars = (sqrt_r, rho_up, rho_lo).
    sqrt_r = s_ref[0]
    rho_up = s_ref[1]
    rho_lo = s_ref[2]
    qv = qv_ref[...]
    n = n_ref[...]
    lower = qv - sqrt_r * n
    upper = qv + sqrt_r * n
    code = jnp.where(lower > rho_up, 1.0, jnp.where(upper < rho_lo, 2.0, 0.0))
    o_ref[...] = jnp.where(m_ref[...] > 0.5, code, 1.0)


@functools.partial(jax.jit, static_argnames=("tb",))
def screen_codes(qv, norms, mask, sqrt_r, rho_up, rho_lo, tb: int = TB):
    """Fused Corollary-3/4 bound check.

    qv, norms, mask: [L] (L % tb == 0); sqrt_r/rho_up/rho_lo: shape-(1,)
    arrays.  Returns f32 codes [L]: 0 keep, 1 -> alpha=0, 2 -> alpha=ub.
    """
    l = qv.shape[0]
    tb = pick_tile(l, tb)
    s = jnp.concatenate([sqrt_r, rho_up, rho_lo]).astype(jnp.float32)
    return pl.pallas_call(
        _screen_kernel,
        grid=(l // tb,),
        in_specs=[
            pl.BlockSpec((3,), lambda i: (0,)),
            pl.BlockSpec((tb,), lambda i: (i,)),
            pl.BlockSpec((tb,), lambda i: (i,)),
            pl.BlockSpec((tb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((l,), jnp.float32),
        interpret=True,
    )(s, qv, norms, mask)
