"""L1 Pallas kernel: one DCDM epoch (Algorithm 2) as a sequential sweep.

DCDM is inherently sequential in its outer loop (each coordinate update
must see the previous one), so the kernel is a single-program
`lax.fori_loop` that keeps alpha in registers/VMEM and streams one row of
Q per step — the TPU analogue of the cache-resident inner loop in the
paper's MATLAB/C implementations.

The nu-SVM dual constraint e^T alpha >= nu is folded into the running
per-coordinate lower bound lb_i = max(0, nu - sum_{k != i} alpha_k)
exactly as Algorithm 2 clips; padded coordinates are made inert by giving
them ub_i = 0 and zero Q rows, so one artifact serves any l <= L.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dcdm_kernel(q_ref, a0_ref, ub_ref, nu_ref, o_ref):
    l = a0_ref.shape[0]
    nu = nu_ref[0]
    ub = ub_ref[...]

    def body(i, alpha):
        qrow = q_ref[i, :]
        g = jnp.dot(qrow, alpha, preferred_element_type=jnp.float32)
        qii = qrow[i]
        rest = jnp.sum(alpha) - alpha[i]
        lb = jnp.maximum(0.0, nu - rest)
        prop = jnp.where(qii > 1e-12, alpha[i] - g / qii, alpha[i])
        new = jnp.clip(prop, lb, ub[i])
        return alpha.at[i].set(new)

    o_ref[...] = jax.lax.fori_loop(0, l, body, a0_ref[...])


@jax.jit
def dcdm_sweep(q, alpha, ub, nu):
    """One full coordinate sweep.  q: [L, L]; alpha, ub: [L]; nu: (1,)."""
    l = alpha.shape[0]
    return pl.pallas_call(
        _dcdm_kernel,
        out_shape=jax.ShapeDtypeStruct((l,), jnp.float32),
        interpret=True,
    )(q, alpha, ub, nu)


@functools.partial(jax.jit, static_argnames=("epochs",))
def dcdm_epochs(q, alpha, ub, nu, epochs: int = 5):
    """`epochs` consecutive sweeps; the Rust caller checks KKT in between."""

    def body(_, a):
        return dcdm_sweep(q, a, ub, nu)

    return jax.lax.fori_loop(0, epochs, body, alpha)
