"""L1 Pallas kernels: tiled Gram-matrix blocks (RBF and linear).

TPU shaping (see DESIGN.md §Hardware-Adaptation): the RBF Gram block is a
matmul in disguise — ||x-y||^2 = ||x||^2 + ||y||^2 - 2 x.y — so the kernel
computes the cross term on the MXU (x1 @ x2.T with
preferred_element_type=f32) and fuses the rank-1 norm corrections plus the
exp epilogue on the VPU inside the same (TM, TN) output tile.  BlockSpec
keeps the feature axis whole in VMEM (F <= 256 after padding), giving one
HBM->VMEM round trip per tile.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO which both jax-CPU (tests)
and the Rust PJRT CPU client (artifacts) execute identically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes.  128x128 matches the MXU systolic array on real TPUs;
# interpret mode does not care but we keep the structure honest.
TM = 128
TN = 128


def _pick(n: int, t: int) -> int:
    """Largest tile <= t dividing n (shapes are static at trace time)."""
    t = min(t, n)
    while n % t != 0:
        t -= 1
    return t


def _rbf_tile_kernel(gamma_ref, x1_ref, x2_ref, o_ref):
    x1 = x1_ref[...]  # [TM, F] resident in VMEM
    x2 = x2_ref[...]  # [TN, F]
    # MXU: cross term.
    cross = jnp.dot(x1, x2.T, preferred_element_type=jnp.float32)
    # VPU epilogue: rank-1 corrections + exp, fused in-tile.
    n1 = jnp.sum(x1 * x1, axis=1, keepdims=True)
    n2 = jnp.sum(x2 * x2, axis=1, keepdims=True)
    d = jnp.maximum(n1 + n2.T - 2.0 * cross, 0.0)
    o_ref[...] = jnp.exp(-gamma_ref[0] * d)


def _linear_tile_kernel(x1_ref, x2_ref, o_ref):
    o_ref[...] = jnp.dot(
        x1_ref[...], x2_ref[...].T, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("tm", "tn"))
def gram_rbf(x1, x2, gamma, tm: int = TM, tn: int = TN):
    """RBF Gram block K[i,j] = exp(-gamma ||x1_i - x2_j||^2).

    x1: [M, F], x2: [N, F] with M % tm == 0 and N % tn == 0 (callers pad).
    gamma: shape-(1,) f32 array (kept as an array so the AOT artifact takes
    it as a runtime input rather than baking it in).
    """
    m, f = x1.shape
    n, _ = x2.shape
    tm, tn = _pick(m, tm), _pick(n, tn)
    grid = (m // tm, n // tn)
    return pl.pallas_call(
        _rbf_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((tm, f), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, f), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(gamma, x1, x2)


@functools.partial(jax.jit, static_argnames=("tm", "tn"))
def gram_linear(x1, x2, tm: int = TM, tn: int = TN):
    """Linear Gram block K = X1 @ X2^T, tiled like gram_rbf."""
    m, f = x1.shape
    n, _ = x2.shape
    tm, tn = _pick(m, tm), _pick(n, tn)
    grid = (m // tm, n // tn)
    return pl.pallas_call(
        _linear_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, f), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, f), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x1, x2)
