"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function here is the mathematically transparent definition of what the
corresponding Pallas kernel in this package must compute.  pytest (with
hypothesis shape/dtype sweeps) asserts allclose between the two.  The Rust
native f64 path mirrors these definitions independently, so the three
implementations triangulate each other.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def sq_dists(x1: jnp.ndarray, x2: jnp.ndarray) -> jnp.ndarray:
    """Pairwise squared Euclidean distances, [M,F] x [N,F] -> [M,N]."""
    n1 = jnp.sum(x1 * x1, axis=1, keepdims=True)
    n2 = jnp.sum(x2 * x2, axis=1, keepdims=True)
    d = n1 + n2.T - 2.0 * (x1 @ x2.T)
    return jnp.maximum(d, 0.0)


def gram_rbf(x1: jnp.ndarray, x2: jnp.ndarray, gamma) -> jnp.ndarray:
    """RBF Gram block: exp(-gamma * ||x_i - x_j||^2)."""
    return jnp.exp(-gamma * sq_dists(x1, x2))


def gram_linear(x1: jnp.ndarray, x2: jnp.ndarray) -> jnp.ndarray:
    """Linear-kernel Gram block: X1 @ X2^T."""
    return x1 @ x2.T


def qmatvec(q: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Gram matvec Q @ v (the screening rule's Z_i . c term)."""
    return q @ v


def screen_codes(
    qv: jnp.ndarray,
    norms: jnp.ndarray,
    mask: jnp.ndarray,
    sqrt_r,
    rho_up,
    rho_lo,
) -> jnp.ndarray:
    """Trinary screening decision per sample (Corollary 3 / 4).

    code 0 = keep (active, goes into the reduced problem)
    code 1 = screened to alpha_i = 0        (sample in R)
    code 2 = screened to alpha_i = ub_i     (sample in L)
    Padded entries (mask == 0) are forced to code 1 so they stay inert.
    """
    lower = qv - sqrt_r * norms
    upper = qv + sqrt_r * norms
    code = jnp.where(lower > rho_up, 1.0, jnp.where(upper < rho_lo, 2.0, 0.0))
    return jnp.where(mask > 0.5, code, 1.0)


def dcdm_sweep(q, alpha, ub, nu) -> jnp.ndarray:
    """One full DCDM epoch (Algorithm 2), sequential over coordinates.

    Exact single-coordinate minimisation of F(a) = 1/2 a^T Q a subject to
    lb_i <= a_i <= ub_i with the running constraint e^T a >= nu folded into
    the per-coordinate lower bound lb_i = max(0, nu - sum_{k != i} a_k),
    exactly as the paper's Algorithm 2 clips.
    """
    qn = np.asarray(q, dtype=np.float64)
    an = np.asarray(alpha, dtype=np.float64).copy()
    ubn = np.asarray(ub, dtype=np.float64)
    l = an.shape[0]
    for i in range(l):
        g = float(qn[i, :] @ an)
        qii = float(qn[i, i])
        rest = float(an.sum() - an[i])
        lb = max(0.0, float(nu) - rest)
        new = an[i] - g / qii if qii > 1e-12 else an[i]
        an[i] = min(max(new, lb), float(ubn[i]))
    return jnp.asarray(an, dtype=jnp.float32)


def decision_rbf(xt, xtr, yalpha, gamma) -> jnp.ndarray:
    """Batched decision scores: K(Xtest, Xtrain) @ (y * alpha)."""
    return gram_rbf(xt, xtr, gamma) @ yalpha


def decision_linear(xt, xtr, yalpha) -> jnp.ndarray:
    return (xt @ xtr.T) @ yalpha
