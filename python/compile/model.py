"""L2: the JAX compute graphs the Rust runtime executes.

Each public function here is a jit-able graph composed from the L1 Pallas
kernels (plus the few ops that belong at graph level: sort/argsort for the
rho-bound order statistic, reductions for r).  `aot.py` lowers each one
once, at fixed padded shapes, to HLO text in artifacts/.

Conventions shared with the Rust runtime (rust/src/runtime/):
  * all tensors f32; scalars travel as shape-(1,) f32 arrays;
  * sample axes are padded to the artifact size; a {0,1} mask marks real
    rows; padded rows carry zero Q rows/cols and ub=0 so they are inert;
  * index arithmetic for Theorem 2 uses `lreal` (true l) not the padded L.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels import dcdm as dcdm_k
from compile.kernels import decision as decision_k
from compile.kernels import gram as gram_k
from compile.kernels import screen as screen_k

# Re-exported kernel graphs (already jitted in their modules).
gram_rbf = gram_k.gram_rbf
gram_linear = gram_k.gram_linear
qmatvec = screen_k.qmatvec
dcdm_epochs = dcdm_k.dcdm_epochs
decision_rbf = decision_k.decision_rbf
decision_linear = decision_k.decision_linear


@jax.jit
def screen_step(q, alpha0, delta, mask, nu1, lreal):
    """One full SRBO screening step (Corollaries 2-4) against Q.

    Inputs
      q      [L, L]  Gram-with-labels matrix Q = diag(y) K diag(y), padded
                     with zero rows/cols beyond lreal
      alpha0 [L]     dual solution at the previous path point nu_0
      delta  [L]     bi-level perturbation (any point of Delta)
      mask   [L]     1.0 for real samples, 0.0 for padding
      nu1    (1,)    next path parameter nu_1 > nu_0
      lreal  (1,)    true sample count l as f32

    Returns (codes[L], rho_up(1,), rho_lo(1,), r(1,)) where codes follow
    ref.screen_codes: 0 keep / 1 -> alpha=0 / 2 -> alpha=1/l.
    """
    v = alpha0 + 0.5 * delta  # c = Z^T v  (Theorem 1)
    qv = qmatvec(q, v)  # Z_i . c for all i  (hot op, Pallas)
    q0 = qmatvec(q, alpha0)
    ctc = jnp.dot(v, qv)  # c^T c     = v^T Q v
    w0w0 = jnp.dot(alpha0, q0)  # w0^T w0   = a0^T Q a0
    r = jnp.maximum(ctc - w0w0, 0.0)  # radius^2 (paper writes |r|)
    sqrt_r = jnp.sqrt(r)

    norms = jnp.sqrt(jnp.maximum(jnp.diagonal(q), 0.0))  # ||Z_i||

    # Theorem 2 order statistic, made safe.  The paper's Eq. (21) reads
    # "bound evaluated at the sorted index", but the provably safe version
    # uses order-statistic dominance: if d_i <= u_i for all i then the
    # k-th largest d is <= the k-th largest u (and symmetrically for the
    # lower bounds).  So rho_up = k-th largest of u = qv + sqrt(r)*n with
    # k = floor(i*), and rho_lo = k'-th largest of lo = qv - sqrt(r)*n
    # with k' = ceil(i*).  See DESIGN.md §6.
    u_bound = jnp.where(mask > 0.5, qv + sqrt_r * norms, -jnp.inf)
    l_bound = jnp.where(mask > 0.5, qv - sqrt_r * norms, -jnp.inf)
    u_sorted = -jnp.sort(-u_bound)  # descending
    l_sorted = -jnp.sort(-l_bound)
    l = lreal[0]
    istar = l - nu1[0] * l  # 1-based rank into d(1) > ... > d(l)
    lmax = jnp.maximum(l - 1.0, 0.0)
    fidx = jnp.clip(jnp.floor(istar) - 1.0, 0.0, lmax).astype(jnp.int32)
    cidx = jnp.clip(jnp.ceil(istar) - 1.0, 0.0, lmax).astype(jnp.int32)
    rho_up = u_sorted[fidx]  # >= d(floor(i*)) >= rho*
    rho_lo = l_sorted[cidx]  # <= d(ceil(i*))  <= rho*

    # Numerical guard (mirrors rust screening::srbo, scaled up for the
    # f32 boundary): alpha0 is eps-accurate and f32 matvecs carry
    # ~sqrt(L)*1e-7 relative noise, so demand a margin beyond the bound
    # before screening — degenerate problems put an atom of samples
    # exactly on the hyperplane where strict comparisons flip on noise.
    # The diag(Q) term covers the absolute gradient-noise floor.
    guard = 1e-4 * (
        jnp.max(jnp.abs(qv)) + jnp.max(jnp.abs(jnp.diagonal(q))) + 1.0
    )

    codes = screen_k.screen_codes(
        qv,
        norms,
        mask,
        sqrt_r.reshape(1),
        (rho_up + guard).reshape(1),
        (rho_lo - guard).reshape(1),
    )
    return codes, rho_up.reshape(1), rho_lo.reshape(1), r.reshape(1)


@functools.partial(jax.jit, static_argnames=("epochs",))
def dcdm_solve(q, alpha, ub, nu, epochs: int = 5):
    """`epochs` DCDM sweeps over the padded dual (Algorithm 2).

    The Rust caller loops this artifact, checking the projected-gradient
    KKT residual natively between calls.
    """
    return dcdm_epochs(q, alpha, ub, nu, epochs=epochs)


@jax.jit
def objective(q, alpha):
    """Dual objective F(alpha) = 1/2 alpha^T Q alpha (safety audits)."""
    return (0.5 * jnp.dot(alpha, qmatvec(q, alpha))).reshape(1)
