"""AOT export: lower every L2 graph to HLO *text* artifacts.

HLO text (not `.serialize()` protos) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Run as `python -m compile.aot --out-dir ../artifacts` (the Makefile does).
Also writes `manifest.tsv`: name, input specs, output arity — the Rust
runtime (rust/src/runtime/artifact.rs) reads it to validate shapes at load
time instead of trusting callers.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Fixed padded artifact shapes, shared with rust/src/runtime/shapes.rs.
L = 512  # padded sample count for screen/dcdm/objective
F = 64  # padded feature count
GM = 256  # gram block rows
GN = 256  # gram block cols
T = 128  # decision test-batch rows
DCDM_EPOCHS = 5


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def artifact_registry():
    """name -> (fn, example arg specs). Single place both layers agree on."""
    s1 = _spec((1,))
    return {
        f"gram_rbf_{GM}x{GN}x{F}": (
            lambda x1, x2, g: (model.gram_rbf(x1, x2, g),),
            [_spec((GM, F)), _spec((GN, F)), s1],
        ),
        f"gram_linear_{GM}x{GN}x{F}": (
            lambda x1, x2: (model.gram_linear(x1, x2),),
            [_spec((GM, F)), _spec((GN, F))],
        ),
        f"qmatvec_{L}": (
            lambda q, v: (model.qmatvec(q, v),),
            [_spec((L, L)), _spec((L,))],
        ),
        f"screen_step_{L}": (
            lambda q, a0, d, m, nu1, lr: model.screen_step(q, a0, d, m, nu1, lr),
            [
                _spec((L, L)),
                _spec((L,)),
                _spec((L,)),
                _spec((L,)),
                s1,
                s1,
            ],
        ),
        f"dcdm_sweep{DCDM_EPOCHS}_{L}": (
            lambda q, a, ub, nu: (
                model.dcdm_solve(q, a, ub, nu, epochs=DCDM_EPOCHS),
            ),
            [_spec((L, L)), _spec((L,)), _spec((L,)), s1],
        ),
        f"decision_rbf_{T}x{L}x{F}": (
            lambda xt, xtr, ya, g: (model.decision_rbf(xt, xtr, ya, g),),
            [_spec((T, F)), _spec((L, F)), _spec((L,)), s1],
        ),
        f"decision_linear_{T}x{L}x{F}": (
            lambda xt, xtr, ya: (model.decision_linear(xt, xtr, ya),),
            [_spec((T, F)), _spec((L, F)), _spec((L,))],
        ),
        f"objective_{L}": (
            lambda q, a: (model.objective(q, a),),
            [_spec((L, L)), _spec((L,))],
        ),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="export a single artifact")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_rows = []
    for name, (fn, specs) in artifact_registry().items():
        if args.only and args.only != name:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        inputs = ";".join(
            "x".join(str(d) for d in s.shape) or "scalar" for s in specs
        )
        nouts = len(fn(*[jnp.zeros(s.shape, s.dtype) for s in specs]))
        manifest_rows.append(f"{name}\t{inputs}\t{nouts}")
        print(f"wrote {path} ({len(text)} chars, {nouts} outputs)")

    if not args.only:
        with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
            f.write("name\tinputs\toutputs\n")
            f.write("\n".join(manifest_rows) + "\n")
        print(f"wrote {args.out_dir}/manifest.tsv ({len(manifest_rows)} artifacts)")


if __name__ == "__main__":
    main()
