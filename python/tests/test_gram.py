"""Pallas Gram kernels vs the pure-jnp oracle (hypothesis shape sweeps)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gram, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand(shape, seed):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


@given(
    mt=st.integers(1, 3),
    nt=st.integers(1, 3),
    f=st.integers(1, 48),
    gamma=st.floats(1e-3, 8.0),
    seed=st.integers(0, 2**16),
)
def test_gram_rbf_matches_ref(mt, nt, f, gamma, seed):
    tm = tn = 16
    x1 = _rand((mt * tm, f), seed)
    x2 = _rand((nt * tn, f), seed + 1)
    g = jnp.array([gamma], jnp.float32)
    out = gram.gram_rbf(x1, x2, g, tm=tm, tn=tn)
    expect = ref.gram_rbf(x1, x2, gamma)
    np.testing.assert_allclose(np.array(out), np.array(expect), rtol=2e-5, atol=2e-6)


@given(
    mt=st.integers(1, 3),
    nt=st.integers(1, 3),
    f=st.integers(1, 48),
    seed=st.integers(0, 2**16),
)
def test_gram_linear_matches_ref(mt, nt, f, seed):
    tm = tn = 16
    x1 = _rand((mt * tm, f), seed)
    x2 = _rand((nt * tn, f), seed + 1)
    out = gram.gram_linear(x1, x2, tm=tm, tn=tn)
    expect = ref.gram_linear(x1, x2)
    np.testing.assert_allclose(np.array(out), np.array(expect), rtol=2e-5, atol=2e-5)


def test_gram_rbf_default_tiles():
    x1 = _rand((256, 64), 7)
    x2 = _rand((128, 64), 8)
    g = jnp.array([0.25], jnp.float32)
    out = gram.gram_rbf(x1, x2, g)
    np.testing.assert_allclose(
        np.array(out), np.array(ref.gram_rbf(x1, x2, 0.25)), rtol=2e-5, atol=2e-6
    )


def test_gram_rbf_diag_is_one():
    x = _rand((128, 16), 9)
    g = jnp.array([1.3], jnp.float32)
    out = np.array(gram.gram_rbf(x, x, g))
    np.testing.assert_allclose(np.diagonal(out), 1.0, atol=1e-5)


def test_gram_rbf_symmetric_psd_ish():
    x = _rand((64, 8), 10)
    g = jnp.array([0.7], jnp.float32)
    k = np.array(gram.gram_rbf(x, x, g, tm=16, tn=16), dtype=np.float64)
    np.testing.assert_allclose(k, k.T, atol=1e-6)
    w = np.linalg.eigvalsh(0.5 * (k + k.T))
    assert w.min() > -1e-4


def test_gram_rbf_range():
    x1 = _rand((32, 4), 11)
    x2 = _rand((32, 4), 12)
    k = np.array(gram.gram_rbf(x1, x2, jnp.array([2.0], jnp.float32), tm=16, tn=16))
    assert (k > 0).all() and (k <= 1.0 + 1e-6).all()
