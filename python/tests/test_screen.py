"""qmatvec + screen_codes kernels and the composed screen_step graph."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref, screen
from tests.helpers import (
    feasible_delta,
    make_problem,
    optimal_delta,
    solve_nu_dual,
)

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


@given(bt=st.integers(1, 4), seed=st.integers(0, 2**16))
def test_qmatvec_matches_ref(bt, seed):
    tb = 16
    l = bt * tb
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(l, l)).astype(np.float32)
    v = rng.normal(size=(l,)).astype(np.float32)
    out = screen.qmatvec(jnp.asarray(q), jnp.asarray(v), tb=tb)
    np.testing.assert_allclose(np.array(out), q @ v, rtol=2e-4, atol=2e-5)


@given(
    bt=st.integers(1, 4),
    sqrt_r=st.floats(0.0, 2.0),
    seed=st.integers(0, 2**16),
)
def test_screen_codes_matches_ref(bt, sqrt_r, seed):
    tb = 16
    l = bt * tb
    rng = np.random.default_rng(seed)
    qv = rng.normal(size=(l,)).astype(np.float32)
    norms = np.abs(rng.normal(size=(l,))).astype(np.float32)
    mask = (rng.uniform(size=l) > 0.2).astype(np.float32)
    up, lo = 0.4, -0.4
    out = screen.screen_codes(
        jnp.asarray(qv),
        jnp.asarray(norms),
        jnp.asarray(mask),
        jnp.array([sqrt_r], jnp.float32),
        jnp.array([up], jnp.float32),
        jnp.array([lo], jnp.float32),
        tb=tb,
    )
    expect = ref.screen_codes(qv, norms, mask, sqrt_r, up, lo)
    np.testing.assert_array_equal(np.array(out), np.array(expect))
    assert set(np.unique(np.array(out))) <= {0.0, 1.0, 2.0}


def test_screen_codes_padding_is_inert():
    l = 32
    qv = np.zeros(l, np.float32)
    norms = np.ones(l, np.float32)
    mask = np.zeros(l, np.float32)
    out = screen.screen_codes(
        jnp.asarray(qv),
        jnp.asarray(norms),
        jnp.asarray(mask),
        jnp.array([0.0], jnp.float32),
        jnp.array([10.0], jnp.float32),
        jnp.array([-10.0], jnp.float32),
        tb=16,
    )
    np.testing.assert_array_equal(np.array(out), np.ones(l, np.float32))


def _screen_safety_case(
    l, nu0, nu1, seed, sep=2.0, use_optimal_delta=False, kernel="rbf"
):
    """Codes from screen_step must never contradict the true alpha(nu1)."""
    _, _, q = make_problem(l=l, seed=seed, separation=sep, kernel=kernel)
    a0 = solve_nu_dual(q, nu0)
    a1 = solve_nu_dual(q, nu1)
    qf = q.astype(np.float32)
    mask = np.ones(l, np.float32)
    # delta must be a member of Delta (Theorem 1); delta = 0 is NOT
    # feasible because sum(alpha0) = nu0 < nu1.
    if use_optimal_delta:
        delta = optimal_delta(q, a0, nu1).astype(np.float32)
    else:
        delta = feasible_delta(a0, nu1).astype(np.float32)
    codes, up, lo, r = model.screen_step(
        jnp.asarray(qf),
        jnp.asarray(a0.astype(np.float32)),
        jnp.asarray(delta),
        jnp.asarray(mask),
        jnp.array([nu1], jnp.float32),
        jnp.array([float(l)], jnp.float32),
    )
    codes = np.array(codes)
    tol = 2e-4
    for i in range(l):
        if codes[i] == 1.0:
            assert a1[i] <= tol, f"code=1 but alpha1[{i}]={a1[i]}"
        elif codes[i] == 2.0:
            assert a1[i] >= 1.0 / l - tol, f"code=2 but alpha1[{i}]={a1[i]}"
    return codes


def test_screen_step_safety_small():
    codes = _screen_safety_case(l=48, nu0=0.3, nu1=0.34, seed=3)
    assert set(np.unique(codes)) <= {0.0, 1.0, 2.0}


def test_screen_step_safety_larger_gap():
    _screen_safety_case(l=64, nu0=0.25, nu1=0.4, seed=5)


def test_screen_step_screens_something_with_optimal_delta():
    """With the bi-level delta* (QPP 18) the sphere tightens enough to
    actually screen on easy data — the cheap feasible delta does not,
    which is exactly the paper's motivation for the bi-level structure
    (Fig. 2 and §3.5)."""
    codes = _screen_safety_case(
        l=64,
        nu0=0.3,
        nu1=0.31,
        seed=7,
        sep=2.4,
        use_optimal_delta=True,
        kernel="linear",
    )
    # Well-separated classes => most samples inactive => some get screened.
    assert (codes != 0.0).sum() > 0


def test_screen_step_r_nonnegative():
    l = 32
    _, _, q = make_problem(l=l, seed=11)
    a0 = solve_nu_dual(q, 0.3)
    delta = feasible_delta(a0, 0.35).astype(np.float32)
    _, _, _, r = model.screen_step(
        jnp.asarray(q.astype(np.float32)),
        jnp.asarray(a0.astype(np.float32)),
        jnp.asarray(delta),
        jnp.asarray(np.ones(l, np.float32)),
        jnp.array([0.35], jnp.float32),
        jnp.array([float(l)], jnp.float32),
    )
    assert float(r[0]) >= 0.0
