"""AOT export sanity: registry lowers, HLO text parses, manifest agrees."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_registry_names_are_unique_and_shaped():
    reg = aot.artifact_registry()
    assert len(reg) >= 8
    for name, (fn, specs) in reg.items():
        assert name.replace("_", "").replace("x", "").isalnum()
        outs = fn(*[jnp.zeros(s.shape, s.dtype) for s in specs])
        assert isinstance(outs, tuple) and len(outs) >= 1


def test_lowering_produces_hlo_text():
    reg = aot.artifact_registry()
    name = f"qmatvec_{aot.L}"
    fn, specs = reg[name]
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[512,512]" in text


@pytest.mark.skipif(not os.path.isdir(ART), reason="artifacts not built")
def test_manifest_matches_files():
    manifest = os.path.join(ART, "manifest.tsv")
    if not os.path.exists(manifest):
        pytest.skip("manifest not built")
    rows = open(manifest).read().strip().splitlines()[1:]
    assert len(rows) >= 8
    for row in rows:
        name, inputs, nouts = row.split("\t")
        path = os.path.join(ART, f"{name}.hlo.txt")
        assert os.path.exists(path), f"missing artifact {name}"
        assert "HloModule" in open(path).read(200)
        assert int(nouts) >= 1


def test_screen_step_artifact_has_sort():
    """The rho-bound order statistic must be present in the lowered HLO."""
    path = os.path.join(ART, f"screen_step_{aot.L}.hlo.txt")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    text = open(path).read()
    assert "sort" in text


def test_l2_no_recomputed_norms_in_gram_hlo():
    """Perf guard (DESIGN §7): reduce for ||x||^2 appears once per operand."""
    path = os.path.join(ART, f"gram_rbf_{aot.GM}x{aot.GN}x{aot.F}.hlo.txt")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    text = open(path).read()
    # the exp epilogue appears exactly once (one op definition; its other
    # mention is the use inside dynamic-update-slice)
    assert text.count(" exponential(") == 1
