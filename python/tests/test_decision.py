"""Decision-scoring kernels vs oracle."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import decision, ref

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _rand(shape, seed):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


@given(
    tt_blocks=st.integers(1, 3),
    l=st.sampled_from([16, 64, 96]),
    f=st.integers(1, 32),
    gamma=st.floats(0.01, 4.0),
    seed=st.integers(0, 2**16),
)
def test_decision_rbf_matches_ref(tt_blocks, l, f, gamma, seed):
    tt = 16
    xt = _rand((tt_blocks * tt, f), seed)
    xtr = _rand((l, f), seed + 1)
    ya = _rand((l,), seed + 2) / l
    out = decision.decision_rbf(
        xt, xtr, ya, jnp.array([gamma], jnp.float32), tt=tt
    )
    expect = ref.decision_rbf(xt, xtr, ya, gamma)
    np.testing.assert_allclose(np.array(out), np.array(expect), rtol=2e-4, atol=2e-5)


@given(
    tt_blocks=st.integers(1, 3),
    l=st.sampled_from([16, 64]),
    f=st.integers(1, 32),
    seed=st.integers(0, 2**16),
)
def test_decision_linear_matches_ref(tt_blocks, l, f, seed):
    tt = 16
    xt = _rand((tt_blocks * tt, f), seed)
    xtr = _rand((l, f), seed + 1)
    ya = _rand((l,), seed + 2) / l
    out = decision.decision_linear(xt, xtr, ya, tt=tt)
    expect = ref.decision_linear(xt, xtr, ya)
    np.testing.assert_allclose(np.array(out), np.array(expect), rtol=2e-4, atol=2e-5)


def test_decision_sign_flip_antisymmetry():
    xt = _rand((32, 8), 1)
    xtr = _rand((64, 8), 2)
    ya = _rand((64,), 3)
    g = jnp.array([0.5], jnp.float32)
    s1 = np.array(decision.decision_rbf(xt, xtr, ya, g, tt=16))
    s2 = np.array(decision.decision_rbf(xt, xtr, -ya, g, tt=16))
    np.testing.assert_allclose(s1, -s2, rtol=1e-5, atol=1e-6)


def test_decision_zero_alpha_gives_zero_scores():
    xt = _rand((16, 4), 4)
    xtr = _rand((32, 4), 5)
    ya = np.zeros(32, np.float32)
    out = np.array(
        decision.decision_rbf(xt, xtr, ya, jnp.array([1.0], jnp.float32), tt=16)
    )
    np.testing.assert_array_equal(out, np.zeros(16, np.float32))
