"""DCDM sweep kernel: oracle match, feasibility, monotone descent."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import dcdm, ref
from tests.helpers import make_problem, solve_nu_dual

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def _feasible_start(l, nu):
    return np.full(l, max(nu / l, 0.0), np.float32)


@given(l=st.sampled_from([16, 32, 48]), nu=st.floats(0.1, 0.6), seed=st.integers(0, 2**12))
def test_dcdm_sweep_matches_ref(l, nu, seed):
    _, _, q = make_problem(l=l, seed=seed)
    qf = q.astype(np.float32)
    a = _feasible_start(l, nu)
    ub = np.full(l, 1.0 / l, np.float32)
    out = dcdm.dcdm_sweep(
        jnp.asarray(qf), jnp.asarray(a), jnp.asarray(ub), jnp.array([nu], jnp.float32)
    )
    expect = ref.dcdm_sweep(qf, a, ub, nu)
    np.testing.assert_allclose(np.array(out), np.array(expect), rtol=1e-4, atol=1e-6)


@given(l=st.sampled_from([16, 32]), nu=st.floats(0.1, 0.7), seed=st.integers(0, 2**12))
def test_dcdm_preserves_feasibility(l, nu, seed):
    _, _, q = make_problem(l=l, seed=seed)
    a = _feasible_start(l, nu)
    ub = np.full(l, 1.0 / l, np.float32)
    cur = jnp.asarray(a)
    for _ in range(3):
        cur = dcdm.dcdm_sweep(
            jnp.asarray(q.astype(np.float32)), cur, jnp.asarray(ub),
            jnp.array([nu], jnp.float32),
        )
        an = np.array(cur)
        assert (an >= -1e-7).all() and (an <= 1.0 / l + 1e-7).all()
        assert an.sum() >= nu - 1e-5


def test_dcdm_descends_objective():
    l, nu = 64, 0.3
    _, _, q = make_problem(l=l, seed=2)
    qf = q.astype(np.float32)
    a = _feasible_start(l, nu)
    ub = np.full(l, 1.0 / l, np.float32)
    f_prev = 0.5 * a @ q @ a
    cur = jnp.asarray(a)
    for _ in range(5):
        cur = dcdm.dcdm_sweep(
            jnp.asarray(qf), cur, jnp.asarray(ub), jnp.array([nu], jnp.float32)
        )
        an = np.array(cur, dtype=np.float64)
        f = 0.5 * an @ q @ an
        assert f <= f_prev + 1e-7
        f_prev = f


def test_dcdm_reaches_coordinatewise_stationarity():
    """Algorithm 2 is single-coordinate descent: on the active constraint
    e^T a = nu it converges to a *coordinate-wise* stationary point (each
    single-coordinate move is blocked or non-improving), which is the
    paper's actual fixed point — visible in Table VIII where DCDM accuracy
    differs from quadprog on Nursery.  The globally exact solver lives in
    the Rust layer (pairwise/SMO refinement).  Here we assert the honest
    property: a further sweep changes nothing and no coordinate move can
    decrease F.
    """
    l, nu = 48, 0.35
    _, _, q = make_problem(l=l, seed=4)
    qf = q.astype(np.float32)
    cur = jnp.asarray(_feasible_start(l, nu))
    ub = np.full(l, 1.0 / l, np.float32)
    cur = dcdm.dcdm_epochs(
        jnp.asarray(qf), cur, jnp.asarray(ub), jnp.array([nu], jnp.float32), epochs=60
    )
    nxt = dcdm.dcdm_sweep(
        jnp.asarray(qf), cur, jnp.asarray(ub), jnp.array([nu], jnp.float32)
    )
    an = np.array(cur, dtype=np.float64)
    np.testing.assert_allclose(np.array(nxt), an, rtol=0, atol=1e-6)
    # no single-coordinate move within the clip bounds can improve
    g = q @ an
    s = an.sum()
    for i in range(l):
        lb = max(0.0, nu - (s - an[i]))
        target = np.clip(an[i] - g[i] / q[i, i], lb, 1.0 / l)
        assert abs(target - an[i]) < 1e-5


def test_dcdm_matches_global_optimum_when_constraint_loose():
    """With the sum constraint slack at the optimum (nu tiny), Algorithm 2
    is plain box-constrained coordinate descent and must hit the global
    minimum of the PSD quadratic."""
    l, nu = 32, 1e-4
    _, _, q = make_problem(l=l, seed=4)
    # shift Q to be strictly positive-definite so the minimum is unique
    q = q + 0.1 * np.eye(l)
    qf = q.astype(np.float32)
    a_star = solve_nu_dual(q, nu)
    f_star = 0.5 * a_star @ q @ a_star
    cur = jnp.asarray(np.full(l, 1.0 / l, np.float32))
    ub = np.full(l, 1.0 / l, np.float32)
    cur = dcdm.dcdm_epochs(
        jnp.asarray(qf), cur, jnp.asarray(ub), jnp.array([nu], jnp.float32), epochs=80
    )
    an = np.array(cur, dtype=np.float64)
    f = 0.5 * an @ q @ an
    assert f <= f_star + 1e-5 * max(1.0, abs(f_star))


def test_dcdm_padding_is_inert():
    l, pad, nu = 32, 16, 0.3
    _, _, q = make_problem(l=l, seed=6)
    lp = l + pad
    qp = np.zeros((lp, lp), np.float32)
    qp[:l, :l] = q
    a = np.zeros(lp, np.float32)
    a[:l] = _feasible_start(l, nu)
    ub = np.zeros(lp, np.float32)
    ub[:l] = 1.0 / l
    out = np.array(
        dcdm.dcdm_sweep(
            jnp.asarray(qp), jnp.asarray(a), jnp.asarray(ub),
            jnp.array([nu], jnp.float32),
        )
    )
    assert (out[l:] == 0.0).all()
    expect = np.array(ref.dcdm_sweep(q.astype(np.float32), a[:l], ub[:l], nu))
    np.testing.assert_allclose(out[:l], expect, rtol=1e-4, atol=1e-6)
