"""Shared test utilities: tiny exact solvers + problem generators.

The projected-gradient solver here is deliberately naive-but-correct: it
is the in-test ground truth used to check that screening codes never
contradict the true optimum (the paper's safety property).
"""

from __future__ import annotations

import numpy as np


def project_box_halfspace(a, ub, nu):
    """Euclidean projection onto {0 <= a <= ub, sum(a) >= nu}.

    If the box clip alone satisfies the halfspace it is the projection;
    otherwise the halfspace is active and KKT gives p = clip(a + t, 0, ub)
    with the shift t applied to the ORIGINAL a (not the clipped one) chosen
    so the sum hits nu — found by bisection (water-filling).
    """
    a = np.asarray(a, dtype=np.float64)
    ub = np.asarray(ub, dtype=np.float64)
    clipped = np.clip(a, 0.0, ub)
    if clipped.sum() >= nu - 1e-15:
        return clipped
    lo, hi = 0.0, float(nu) - float(np.min(a)) + float(np.max(ub)) + 1.0
    for _ in range(200):
        t = 0.5 * (lo + hi)
        s = np.clip(a + t, 0.0, ub).sum()
        if s < nu:
            lo = t
        else:
            hi = t
    return np.clip(a + hi, 0.0, ub)


def solve_nu_dual(q, nu, ub=None, iters=20000, tol=1e-12):
    """min 1/2 a^T Q a over {0 <= a <= ub, sum >= nu} by projected gradient."""
    l = q.shape[0]
    if ub is None:
        ub = np.full(l, 1.0 / l)
    lam = np.linalg.eigvalsh(q).max()
    step = 1.0 / max(lam, 1e-12)
    a = project_box_halfspace(np.full(l, nu / l), ub, nu)
    prev = np.inf
    for _ in range(iters):
        g = q @ a
        a = project_box_halfspace(a - step * g, ub, nu)
        f = 0.5 * a @ q @ a
        if abs(prev - f) < tol * max(1.0, abs(f)):
            break
        prev = f
    return a


def feasible_delta(alpha0, nu1, ub=None):
    """A cheap member of Delta = {d | sum(a0+d) >= nu1, 0 <= a0+d <= ub}.

    Distributes the mass shortfall (nu1 - sum(a0)) proportionally to each
    coordinate's headroom ub_i - a0_i.  This is the warm-start delta the
    Rust bi-level optimiser refines (Eq. 27)."""
    a0 = np.asarray(alpha0, dtype=np.float64)
    l = a0.shape[0]
    if ub is None:
        ub = np.full(l, 1.0 / l)
    need = max(0.0, float(nu1) - float(a0.sum()))
    head = np.maximum(ub - a0, 0.0)
    total = head.sum()
    if need <= 0.0 or total <= 0.0:
        return np.zeros(l)
    return head * min(1.0, need / total)


def optimal_delta(q, alpha0, nu1, ub=None, iters=4000):
    """The bi-level delta* of QPP (18): argmin_{delta in Delta} r(delta).

    Substituting beta = alpha0 + delta turns it into min over beta in
    A_{nu1} of 1/4 (b-a0)^T Q (b-a0) + a0^T Q (b-a0), with gradient
    (1/2) Q (b + a0) — solved by projected gradient."""
    a0 = np.asarray(alpha0, dtype=np.float64)
    l = a0.shape[0]
    if ub is None:
        ub = np.full(l, 1.0 / l)
    lam = np.linalg.eigvalsh(q).max()
    step = 2.0 / max(lam, 1e-12)
    b = project_box_halfspace(a0 + feasible_delta(a0, nu1, ub), ub, nu1)
    for _ in range(iters):
        g = 0.5 * (q @ (b + a0))
        b = project_box_halfspace(b - step * g, ub, nu1)
    return b - a0


def make_problem(l=64, p=4, gamma=0.5, seed=0, separation=2.0, kernel="rbf"):
    """Two-Gaussian binary task with its Q matrix (float64).

    kernel="linear" folds the bias (Phi(x) <- [x, 1], paper Eq. 2)."""
    rng = np.random.default_rng(seed)
    half = l // 2
    xp = rng.normal(loc=separation / 2, size=(half, p))
    xn = rng.normal(loc=-separation / 2, size=(l - half, p))
    x = np.vstack([xp, xn])
    y = np.concatenate([np.ones(half), -np.ones(l - half)])
    if kernel == "linear":
        xb = np.hstack([x, np.ones((l, 1))])
        k = xb @ xb.T
    else:
        d = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
        k = np.exp(-gamma * d)
    q = np.outer(y, y) * k
    # Symmetrise to kill accumulation asymmetry.
    q = 0.5 * (q + q.T)
    return x.astype(np.float32), y.astype(np.float32), q
