# SRBO-ν-SVM build entrypoints — humans and CI run the identical pipeline.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: all verify verify-matrix lint fmt bench-compile bench bench-gram bench-path bench-dcdm bench-drift bench-serve bench-regress aot clean

all: verify

# Tier-1 verify (verbatim — keep in sync with ROADMAP.md and CI).
# NOTE: this is the tier-1 gate only; CI additionally fans the
# conformance + safety suites over every gram policy × gap-screening
# toggle.  Run `make verify-matrix` to reproduce that locally.
verify:
	$(CARGO) build --release && $(CARGO) test -q
	@echo "tier-1 OK — run 'make verify-matrix' for the CI gram × dynamic matrix"

# Local mirror of CI's gram-matrix job: the conformance + safety suites
# once per kernel-matrix policy, each with gap-safe dynamic screening
# forced on and off (8 runs), then one fault-injection leg
# (SRBO_TEST_FAULTS=on) re-running the durability + serving audits under
# injected torn writes, transient reads, and eval panics.
verify-matrix:
	@set -e; for g in dense lru sharded stream; do \
		for dyn in on off; do \
			echo "== SRBO_TEST_GRAM=$$g SRBO_TEST_DYNAMIC=$$dyn =="; \
			SRBO_TEST_GRAM=$$g SRBO_TEST_DYNAMIC=$$dyn \
				$(CARGO) test -q --test conformance --test safety; \
		done; \
	done
	@echo "== SRBO_TEST_FAULTS=on =="
	@SRBO_TEST_FAULTS=on $(CARGO) test -q --test faults --test serve

# Lint gate: formatting + clippy with warnings denied.
lint:
	$(CARGO) fmt --check
	$(CARGO) clippy --all-targets -- -D warnings

fmt:
	$(CARGO) fmt

# Compile all bench targets (12 paper tables/figures + gram_build)
# without running them.
bench-compile:
	$(CARGO) bench --no-run

# Run the full paper evaluation (slow; SRBO_SCALE shrinks it).
bench:
	$(CARGO) bench

# Gram-build scaling bench (threads × size grid) → BENCH_gram.json.
bench-gram:
	$(CARGO) bench --bench gram_build

# Shard-parallel path bench (threads × size × backend grid) →
# BENCH_path.json.  SRBO_BENCH_QUICK=1 runs the CI smoke grid.
bench-path:
	$(CARGO) bench --bench path_scale

# DCDM solver bench (size × shrink × gap × gbar × selection × backend
# grid) → BENCH_dcdm.json.  SRBO_BENCH_QUICK=1 runs the CI smoke grid.
bench-dcdm:
	$(CARGO) bench --bench dcdm_scale

# Incremental-training bench (warm resume vs cold over a mutation
# fraction × size grid) → BENCH_drift.json.  SRBO_BENCH_QUICK=1 runs
# the CI smoke grid.
bench-drift:
	$(CARGO) bench --bench drift_scale

# Serving bench (batch × clients × family grid through the loopback
# serve loop) → BENCH_serve.json.  SRBO_BENCH_QUICK=1 runs the CI
# smoke grid.
bench-serve:
	$(CARGO) bench --bench serve_scale

# Regression gate: rerun the dcdm + drift + serve benches and compare
# medians against the committed BENCH_*.json baselines (>25% median
# wall-time regression on any matching run fails; skips cleanly when no
# baseline is committed).  CI runs the same script after its quick-mode
# smoke.
bench-regress: bench-dcdm bench-drift bench-serve
	./scripts/bench_regress.sh BENCH_dcdm.json
	./scripts/bench_regress.sh BENCH_drift.json
	./scripts/bench_regress.sh BENCH_serve.json

# Optional: export the L2 JAX/Pallas graphs to artifacts/*.hlo.txt.
# Needs the Python toolchain (jax); the Rust `pjrt` feature consumes the
# result. The default Rust build does NOT require this.
aot:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

clean:
	$(CARGO) clean
	rm -rf artifacts python/compile/__pycache__ python/compile/kernels/__pycache__
